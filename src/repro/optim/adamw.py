"""AdamW + schedules in pure JAX (optax is not available offline).

State layout mirrors the param tree ({'m': tree, 'v': tree, 'step': scalar})
so the distribution layer can shard optimizer state exactly like parameters.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, 1.0) * jnp.where(s < cfg.warmup_steps, 1.0, cos)


def init_state(params: dict) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(abstract_params: dict) -> dict:
    z = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params
    )
    return {
        "m": z,
        "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), z),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
    )


def apply_updates(cfg: AdamWConfig, params: dict, grads: dict, state: dict):
    """One AdamW step (with global-norm clipping). Returns (params, state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
