"""repro: GeoTP (latency-aware geo-distributed transaction processing) as a
production-grade multi-pod JAX framework.

Layers:
  repro.core     — the paper's contribution (decentralized prepare, latency-aware
                   scheduling, hotspot heuristics) + discrete-event engine + baselines.
  repro.models   — LM substrate for the 10 assigned architectures.
  repro.dist     — sharding rules, checkpointing (GeoTP one-round commit), elastic,
                   gradient compression.
  repro.serving  — continuous-batching geo-serving engine (GeoTP as router feature).
  repro.kernels  — Pallas TPU kernels (interpret-validated on CPU).
  repro.launch   — mesh / dryrun / train / serve / roofline entrypoints.
"""

__version__ = "1.0.0"
