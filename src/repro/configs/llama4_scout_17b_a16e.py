"""Assigned architecture config: llama4-scout-17b-a16e (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("llama4-scout-17b-a16e")
