"""Architecture registry: `get(name)` / `reduced(name)` for every assigned
config. Each arch also has a module `repro.configs.<id>` exposing CONFIG."""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    return _REGISTRY[name]


def names() -> list:
    return sorted(_REGISTRY.keys())


def reduced(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: few layers, narrow width,
    few experts, small vocab — identical block structure."""
    cfg = get(name)
    period = len(cfg.pattern)
    tail = cfg.tail
    n_layers = period + len(tail)  # one scanned group + the tail
    d_model = 128
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4
    changes = dict(
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        window=min(cfg.window, 64),
        max_seq=256,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        capacity_factor=8.0,  # no-drop in tests => decode == train exactly
        q_lora_rank=64,
        kv_lora_rank=32,
        rope_head_dim=16,
        nope_head_dim=32,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        frontend_dim=64 if cfg.frontend != "none" else 0,
    )
    return dataclasses.replace(cfg, **changes)


# --- dense -------------------------------------------------------------------

QWEN2_72B = register(
    ModelConfig(
        name="qwen2-72b",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab=152064,
        qkv_bias=True,  # Qwen2 uses QKV bias [arXiv:2407.10671]
        rope_theta=1_000_000.0,
        pattern=(("gqa", "dense"),),
    )
)

MINICPM3_4B = register(
    ModelConfig(
        name="minicpm3-4b",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab=73448,
        pattern=(("mla", "dense"),),  # MLA [hf:openbmb/MiniCPM3-4B]
        q_lora_rank=768,
        kv_lora_rank=256,
        rope_head_dim=32,
        nope_head_dim=64,
        tie_embeddings=True,
    )
)

H2O_DANUBE3_4B = register(
    ModelConfig(
        name="h2o-danube-3-4b",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        d_ff=10240,
        vocab=32000,
        head_dim=120,
        pattern=(("swa", "dense"),),  # llama+mistral mix, sliding window
        window=4096,
        rope_theta=10_000.0,
    )
)

LLAMA32_3B = register(
    ModelConfig(
        name="llama3.2-3b",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab=128256,
        rope_theta=500_000.0,
        pattern=(("gqa", "dense"),),
        tie_embeddings=True,
    )
)

# --- ssm ----------------------------------------------------------------------

XLSTM_350M = register(
    ModelConfig(
        name="xlstm-350m",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,  # blocks carry their own projections
        vocab=50304,
        # xLSTM[7:1]: seven mLSTM blocks per sLSTM block [arXiv:2405.04517]
        pattern=(
            ("mlstm", "none"),
            ("mlstm", "none"),
            ("mlstm", "none"),
            ("slstm", "none"),
            ("mlstm", "none"),
            ("mlstm", "none"),
            ("mlstm", "none"),
            ("mlstm", "none"),
        ),
    )
)

# --- audio enc-dec -------------------------------------------------------------

SEAMLESS_M4T_LARGE_V2 = register(
    ModelConfig(
        name="seamless-m4t-large-v2",
        n_layers=24,
        n_enc_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=256206,
        norm="layernorm",
        act="gelu",
        pattern=(("gqa", "dense"),),
        frontend="audio",
        frontend_dim=160,  # fbank-frame stub embeddings [arXiv:2308.11596]
    )
)

# --- moe -----------------------------------------------------------------------

MIXTRAL_8X7B = register(
    ModelConfig(
        name="mixtral-8x7b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=32000,
        pattern=(("swa", "moe"),),  # 8 experts top-2 + SWA [arXiv:2401.04088]
        window=4096,
        n_experts=8,
        top_k=2,
        rope_theta=1_000_000.0,
    )
)

LLAMA4_SCOUT = register(
    ModelConfig(
        name="llama4-scout-17b-a16e",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab=202048,
        # iRoPE-style: 3 chunked-local layers + 1 global NoPE layer; MoE 16e top-1
        pattern=(
            ("cla", "moe"),
            ("cla", "moe"),
            ("cla", "moe"),
            ("gqa", "moe"),
        ),
        window=8192,
        n_experts=16,
        top_k=1,
        rope_theta=500_000.0,
    )
)

# --- vlm -----------------------------------------------------------------------

INTERNVL2_26B = register(
    ModelConfig(
        name="internvl2-26b",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=92553,
        pattern=(("gqa", "dense"),),
        frontend="vision",
        frontend_dim=3200,  # InternViT-6B patch-embedding stub [arXiv:2404.16821]
        rope_theta=1_000_000.0,
    )
)

# --- hybrid ---------------------------------------------------------------------

RECURRENTGEMMA_9B = register(
    ModelConfig(
        name="recurrentgemma-9b",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,  # MQA
        d_ff=12288,
        vocab=256000,
        # Griffin 1:2 — (rglru, rglru, local attn) x 12, tail (rglru, rglru)
        pattern=(("rglru", "dense"), ("rglru", "dense"), ("swa", "dense")),
        tail=(("rglru", "dense"), ("rglru", "dense")),
        window=2048,
        act="gelu",
        attn_softcap=50.0,
        rnn_scale=1.0,
        tie_embeddings=True,
    )
)

ALL_ARCHS = names()
