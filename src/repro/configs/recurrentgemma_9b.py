"""Assigned architecture config: recurrentgemma-9b (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("recurrentgemma-9b")
