"""Assigned architecture config: llama3.2-3b (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("llama3.2-3b")
