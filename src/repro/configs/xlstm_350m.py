"""Assigned architecture config: xlstm-350m (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("xlstm-350m")
