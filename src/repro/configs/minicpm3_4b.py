"""Assigned architecture config: minicpm3-4b (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("minicpm3-4b")
