"""Assigned architecture config: mixtral-8x7b (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("mixtral-8x7b")
