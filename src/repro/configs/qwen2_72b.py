"""Assigned architecture config: qwen2-72b (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("qwen2-72b")
