"""Assigned architecture config: internvl2-26b (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("internvl2-26b")
