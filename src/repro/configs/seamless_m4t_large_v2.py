"""Assigned architecture config: seamless-m4t-large-v2 (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("seamless-m4t-large-v2")
