"""Assigned architecture config: h2o-danube-3-4b (see registry.py for parameters)."""

from repro.configs.registry import get

CONFIG = get("h2o-danube-3-4b")
