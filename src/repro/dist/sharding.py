"""Logical-axis -> mesh-axis sharding rules and tree-level sharding builders.

The model schema (repro.models.schema) names every weight dim with a logical
axis ("embed", "heads", "mlp", ...); this module maps those names onto mesh
axes per execution mode and materializes NamedSharding trees for params,
optimizer state and input batches. `repro.launch.dryrun`/`perf` consume these
to lower cells with explicit in/out shardings.
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import WORLDS_AXIS, data_axes


# ---------------------------------------------------------------------------
# engine world-batch sharding (the `strategy="mesh"` placement rules)
# ---------------------------------------------------------------------------


def worlds_pspec(batched: bool = True) -> P:
    """PartitionSpec for one engine batch leaf: leading [B] axis over the
    1-D "worlds" mesh; unbatched (shared) leaves replicate. Worlds are
    independent, so leading-axis sharding is the complete rule set — no
    inner dim of `WorldSpec`/`Bank`/`SimState` ever crosses a device."""
    return P(WORLDS_AXIS) if batched else P()


def world_shardings(mesh: Mesh, tree, batched: bool = True):
    """NamedSharding tree for a stacked engine pytree (WorldSpec / Bank /
    SimState): every leaf sharded on its leading batch dim over "worlds"
    (replicated when ``batched=False`` — e.g. a Bank shared by all cells)."""
    import jax

    sh = NamedSharding(mesh, worlds_pspec(batched))
    return jax.tree_util.tree_map(lambda _: sh, tree)


def place_worlds(tree, mesh: Mesh, batched: bool = True):
    """Pin a stacked engine pytree onto the worlds mesh (usable under jit:
    `with_sharding_constraint` so the compiler materializes the leading-axis
    layout before `shard_map` consumes it)."""
    import jax

    return jax.lax.with_sharding_constraint(tree, world_shardings(mesh, tree, batched))


def train_rules(mesh: Mesh) -> dict:
    """FSDP storage over the data axes, tensor parallelism over "model".

    "embed" is the FSDP axis (params sharded over data for storage; gathered
    per layer under jit), the wide dims shard over the model axis.
    """
    data = data_axes(mesh)
    return {
        "embed": data if len(data) > 1 else (data[0] if data else None),
        "vocab": "model",
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "experts": "model",
        "layers": None,
        "state": None,
        "conv": None,
    }


def decode_rules(mesh: Mesh) -> dict:
    """Pure tensor parallelism: params replicated over data, sharded over
    "model" on the wide dims (decode batches are too small for FSDP)."""
    return {
        "embed": None,
        "vocab": "model",
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "experts": "model",
        "layers": None,
        "state": None,
        "conv": None,
    }


def rules_for(mesh: Mesh, mode: str) -> dict:
    return train_rules(mesh) if mode == "train" else decode_rules(mesh)


def param_shardings(cfg, mesh: Mesh, mode: str = "train") -> dict:
    """NamedSharding tree matching the arch's parameter schema."""
    from repro.models import schema, stack

    return schema.shardings(stack.build_schema(cfg), rules_for(mesh, mode), mesh)


def opt_shardings(param_sh: dict, mesh: Mesh) -> dict:
    """AdamW state tree: moments follow the params, the step is replicated."""
    return {
        "m": param_sh,
        "v": param_sh,
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(mesh: Mesh, batch_spec: dict) -> dict:
    """Shard every batch leaf on its leading (batch) dim over the data axes;
    replicate dims the axis size does not divide (same guard as the schema)."""
    import math

    import jax

    data = data_axes(mesh)
    size = math.prod(mesh.shape[a] for a in data) if data else 1
    axis = data if len(data) > 1 else (data[0] if data else None)

    def one(spec):
        if axis is None or spec.shape == () or spec.shape[0] % size:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(axis, *([None] * (len(spec.shape) - 1))))

    return jax.tree.map(one, batch_spec)
