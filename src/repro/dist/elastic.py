"""Elastic resizing: carry a training job across host-set changes.

A committed one-round checkpoint (repro.dist.checkpoint) is the handoff
point: on resize we re-plan the data-parallel split for the new host count
and tell each new host which old shards to read. Shards are replicated
param trees (every host holds the full tree in the reduced local setup), so
resize = re-assign data ranges; the plan generalizes to sharded layouts by
mapping shard ranges instead.

This is the training-infrastructure face of the same crash/recovery story
the engine simulates: the ``faults`` Grid axis
(`repro.core.engine.Grid`, `SimConfig.max_faults`) injects deterministic
data-source outages into the transaction simulation, while `plan_resize` +
`CheckpointManager.recover` handle the real host-set change on this side.
Property tests over old x new host sweeps live in tests/dist/.
"""

from __future__ import annotations

from typing import NamedTuple


class ResizePlan(NamedTuple):
    old_hosts: int
    new_hosts: int
    # per new host: list of old-host shard ids to read (usually length 1)
    sources: tuple
    # per new host: (start, stop) fraction of the global batch it now owns
    batch_ranges: tuple


def plan_resize(old_hosts: int, new_hosts: int) -> ResizePlan:
    """Map every new host onto the old shard set + its new batch range."""
    assert old_hosts >= 1 and new_hosts >= 1
    sources = tuple((h % old_hosts,) for h in range(new_hosts))
    ranges = tuple(
        (h / new_hosts, (h + 1) / new_hosts) for h in range(new_hosts)
    )
    return ResizePlan(old_hosts, new_hosts, sources, ranges)


def local_batch(global_batch: int, plan: ResizePlan, host: int) -> tuple:
    """Integer [start, stop) rows of the global batch owned by `host`."""
    lo, hi = plan.batch_ranges[host]
    return int(round(lo * global_batch)), int(round(hi * global_batch))


def validate(plan: ResizePlan, global_batch: int) -> bool:
    """Ranges must tile the batch exactly — no dropped or duplicated rows."""
    edges = [local_batch(global_batch, plan, h) for h in range(plan.new_hosts)]
    ok = edges[0][0] == 0 and edges[-1][1] == global_batch
    for (a, b), (c, d) in zip(edges, edges[1:]):
        ok = ok and b == c
    return ok
