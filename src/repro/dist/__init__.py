"""Distributed substrate: sharding rules, GeoTP one-round-commit
checkpointing, gradient compression and elastic resizing.

The checkpoint manager mirrors the paper's commit-protocol insight at the
training layer: every host writes its shard (decentralized prepare — the
write IS the vote), then a single atomic commit marker finalizes the step,
so recovery never needs a second round of coordination.
"""

from repro.dist import checkpoint, compression, elastic, sharding

__all__ = ["checkpoint", "compression", "elastic", "sharding"]
