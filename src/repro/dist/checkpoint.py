"""GeoTP one-round-commit checkpointing.

Protocol (the paper's decentralized-prepare idea applied to checkpoint I/O):

  1. `write_shard(step, host, tree)` — each host streams its shard to
     `step_<N>/shard_<h>.npz` and drops `shard_<h>.ok` beside it. The
     durable shard write IS the prepare vote: no separate vote round.
  2. `commit(step)` — once every host's `.ok` marker exists, an atomic
     rename publishes `step_<N>/COMMIT`. One round total.
  3. `recover()` — scans for the newest directory with a COMMIT marker and
     garbage-collects uncommitted leftovers (crash mid-prepare leaves no
     torn state: without COMMIT the step never happened).

Trees are flattened with '/'-joined key paths into one npz per host shard.

A host crashing mid-prepare here (shard written, COMMIT absent) is the
filesystem analogue of the engine's deterministic fault injection — the
``faults`` Grid axis crashes a simulated data source mid-prepare and drives
the peer-abort path; `recover` plays the same role for checkpoint state:
without COMMIT the step never happened. tests/dist/ asserts both halves of
that contract.
"""

from __future__ import annotations

import os
import pathlib
import shutil

import jax
import numpy as np

_STEP_PREFIX = "step_"
_COMMIT = "COMMIT"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, root, n_hosts: int = 1):
        self.root = pathlib.Path(root)
        self.n_hosts = n_hosts
        self.root.mkdir(parents=True, exist_ok=True)

    # ---- paths ------------------------------------------------------------
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.root / f"{_STEP_PREFIX}{step:08d}"

    def _shard(self, step: int, host: int) -> pathlib.Path:
        return self._step_dir(step) / f"shard_{host:04d}.npz"

    # ---- one-round commit -------------------------------------------------
    def write_shard(self, step: int, host: int, tree) -> None:
        """Durable shard write + prepare marker (the vote)."""
        d = self._step_dir(step)
        d.mkdir(parents=True, exist_ok=True)
        shard = self._shard(step, host)
        tmp = shard.with_suffix(".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **_flatten(tree))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, shard)  # atomic: a shard is either whole or absent
        (d / f"shard_{host:04d}.ok").touch()

    def prepared(self, step: int) -> bool:
        d = self._step_dir(step)
        return all((d / f"shard_{h:04d}.ok").exists() for h in range(self.n_hosts))

    def commit(self, step: int) -> bool:
        """Publish the step iff every host voted. Atomic, idempotent."""
        if not self.prepared(step):
            return False
        d = self._step_dir(step)
        tmp = d / (_COMMIT + ".tmp")
        tmp.touch()
        os.replace(tmp, d / _COMMIT)
        return True

    # ---- recovery ---------------------------------------------------------
    def _steps(self, committed_only: bool) -> list:
        steps = []
        for d in self.root.glob(_STEP_PREFIX + "*"):
            if not d.is_dir():
                continue
            if committed_only and not (d / _COMMIT).exists():
                continue
            try:
                steps.append(int(d.name[len(_STEP_PREFIX):]))
            except ValueError:
                continue
        return sorted(steps)

    def latest_step(self):
        steps = self._steps(committed_only=True)
        return steps[-1] if steps else None

    def recover(self):
        """Latest committed step (or None); removes uncommitted leftovers."""
        latest = self.latest_step()
        for step in self._steps(committed_only=False):
            if not (self._step_dir(step) / _COMMIT).exists():
                shutil.rmtree(self._step_dir(step), ignore_errors=True)
        return latest

    def restore(self, step: int, host: int, like):
        """Load host's shard into the structure of `like` (path-keyed)."""
        with np.load(self._shard(step, host)) as z:
            flat = {k: z[k] for k in z.files}
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = flat[key]
            leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
