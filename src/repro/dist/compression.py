"""Int8 gradient compression with error feedback for the cross-pod axis.

Cross-pod (DCN) all-reduces are the WAN of the training stack — the same
bandwidth-bound hop the paper's middleware optimizes. Gradients are
quantized to int8 with one float32 scale per tensor; the quantization
residual is carried forward and added to the next step's gradient (error
feedback), so the compressed SGD trajectory stays unbiased in the long run.

    state = init_error(grads)
    q, state = compress(grads, state)     # ship q (int8 + scales)
    grads = decompress(q)                 # after the DCN all-reduce
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: dict  # tree of int8 tensors
    scale: dict  # tree of float32 scalars (absmax / 127)


def init_error(grads) -> dict:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _q_one(g, err):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_err = g - q.astype(jnp.float32) * scale
    return q, scale, new_err


def compress(grads, error) -> tuple:
    """(grads, error) -> (Compressed, new_error). Tree-structured."""
    qs = jax.tree.map(lambda g, e: _q_one(g, e), grads, error)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    scale = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[2], qs, is_leaf=lambda x: isinstance(x, tuple))
    return Compressed(q=q, scale=scale), err


def decompress(c: Compressed):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, c.q, c.scale
    )


def compression_ratio(grads) -> float:
    """Bytes saved: fp32 -> int8 + one scale per tensor."""
    orig = sum(g.size * 4 for g in jax.tree.leaves(grads))
    comp = sum(g.size + 4 for g in jax.tree.leaves(grads))
    return orig / max(comp, 1)
