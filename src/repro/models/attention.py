"""Attention mixers: GQA (full / sliding-window / chunked-local) and MLA.

Training/prefill uses a query-chunked online-softmax formulation (flash-style
in pure JAX): activations stay O(S * chunk) instead of O(S^2), which is what
makes the 32k prefill cells lowerable, and windowed variants only read the KV
band they need (so HLO FLOPs reflect the true sub-quadratic cost).

Decode paths operate on KV caches:
  * full attention  — linear cache [B, S, kv, hd]
  * swa / cla       — ring-buffer cache [B, window, kv, hd]  (bounded state)
  * mla             — compressed latent cache [B, S, kv_lora + rope_dim]

The Pallas kernels in repro.kernels implement the same contracts for TPU; the
functions here are the reference paths (and what the CPU dry-run lowers).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, softcap

_NEG = -1e30


def _online_merge(acc, m, l, scores, v):
    """One online-softmax accumulation step. scores: [..., q, k], v: [..., k, d]."""
    m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m - m_new)
    pexp = jnp.exp(scores - m_new[..., None])
    l_new = l * alpha + jnp.sum(pexp, axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum("...qk,...kd->...qd", pexp, v)
    return acc_new, m_new, l_new


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk_local: bool = False,
    q_chunk: int = 512,
    logit_cap: float = 0.0,
) -> jax.Array:
    """q: [B,S,H,dh], k/v: [B,S,KV,dh(v)] -> [B,S,H,dhv].

    window>0: sliding-window (swa) or same-chunk (cla when chunk_local) mask,
    reading only the KV band [chunk_start - band, chunk_end).
    """
    B, S, H, dh = q.shape
    S_kv = k.shape[1]
    KV = k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    scale = dh**-0.5
    qc = min(q_chunk, S)
    n_chunks = S // qc
    assert S % qc == 0, (S, qc)
    assert (not causal) or S == S_kv, "causal attention needs q_len == kv_len"

    # [B,KV,G,S,dh] layout so kv heads broadcast over the group dim
    qg = q.reshape(B, S, KV, G, dh).transpose(0, 2, 3, 1, 4)
    kk = k.transpose(0, 2, 1, 3)  # [B,KV,S,dh]
    vv = v.transpose(0, 2, 1, 3)  # [B,KV,S,dv]

    band = 0
    if window and window < S_kv:
        band = min(window + qc, S_kv) if not chunk_local else min(2 * window, S_kv)

    def one_chunk(ci):
        q0 = ci * qc
        qi = jax.lax.dynamic_slice_in_dim(qg, q0, qc, axis=3)  # [B,KV,G,qc,dh]
        if band:
            k0 = jnp.maximum(q0 + qc - band, 0)
            ks = jax.lax.dynamic_slice_in_dim(kk, k0, band, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(vv, k0, band, axis=2)
            kpos = k0 + jnp.arange(band)
        else:
            ks, vs = kk, vv
            kpos = jnp.arange(S_kv)
            k0 = 0
        s = jnp.einsum("bngqd,bnkd->bngqk", qi, ks).astype(jnp.float32) * scale
        s = softcap(s, logit_cap)
        qpos = q0 + jnp.arange(qc)
        mask = jnp.ones((qc, kpos.shape[0]), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window and window < S_kv:
            if chunk_local:
                mask &= (kpos[None, :] // window) == (qpos[:, None] // window)
            else:
                mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bngqk,bnkd->bngqd", p.astype(vs.dtype), vs)
        return o  # [B,KV,G,qc,dv]

    if n_chunks == 1:
        out = one_chunk(0)  # [B,KV,G,S,dv]
    else:
        outs = jax.lax.map(one_chunk, jnp.arange(n_chunks))  # [C,B,KV,G,qc,dv]
        out = jnp.moveaxis(outs, 0, 3).reshape(B, KV, G, S, dv)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, dv)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    valid: jax.Array,
    *,
    logit_cap: float = 0.0,
) -> jax.Array:
    """Single-position decode. q: [B,1,H,dh]; caches [B,Sc,KV,dh(v)];
    valid: [B,Sc] bool — which cache slots participate."""
    B, _, H, dh = q.shape
    Sc, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = dh**-0.5
    qg = q.reshape(B, KV, G, dh)
    s = jnp.einsum("bngd,bsnd->bngs", qg, k_cache).astype(jnp.float32) * scale
    s = softcap(s, logit_cap)
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngs,bsnd->bngd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, v_cache.shape[-1])


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def gqa_project_qkv(cfg, p, prefix, x, positions, use_rope=True):
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p[f"{prefix}.wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dnk->bsnk", x, p[f"{prefix}.wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnk->bsnk", x, p[f"{prefix}.wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p[f"{prefix}.bq"].astype(x.dtype)
        k = k + p[f"{prefix}.bk"].astype(x.dtype)
        v = v + p[f"{prefix}.bv"].astype(x.dtype)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attn(cfg, p, prefix, x, positions, *, mixer: str, causal=True, kv=None):
    """Train/prefill GQA. Returns (out, (k, v)) — k/v for cache construction."""
    window = cfg.window if mixer in ("swa", "cla") else 0
    use_rope = not (mixer == "gqa" and cfg.name.startswith("llama4"))  # iRoPE: NoPE on global layers
    q, k, v = gqa_project_qkv(cfg, p, prefix, x, positions, use_rope)
    o = chunked_attention(
        q,
        k,
        v,
        causal=causal,
        window=window,
        chunk_local=(mixer == "cla"),
        logit_cap=cfg.attn_softcap,
    )
    out = jnp.einsum("bshk,hkd->bsd", o, p[f"{prefix}.wo"].astype(x.dtype))
    return out, (k, v)


def _kv_quantize(x: jax.Array):
    """Per-(token, head) symmetric int8 quantization. x: [B,KV,hd]."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.float32)


def _kv_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """q: [B,S,KV,hd], scale: [B,S,KV]."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def gqa_decode(cfg, p, prefix, x, pos, cache, *, mixer: str):
    """One-token decode step. cache: dict(k, v[, k_scale, v_scale]).
    Ring buffer for swa/cla; optional int8-quantized cache (kv_cache_dtype)."""
    B = x.shape[0]
    positions = pos[:, None]  # [B,1]
    use_rope = not (mixer == "gqa" and cfg.name.startswith("llama4"))
    q, k, v = gqa_project_qkv(cfg, p, prefix, x, positions, use_rope)
    k_cache, v_cache = cache["k"], cache["v"]
    Sc = k_cache.shape[1]
    slot = pos % Sc  # ring position (== pos for linear caches, Sc >= max_seq)
    bidx = jnp.arange(B)
    quant = cfg.kv_cache_dtype == "int8"
    if quant:
        kq, ks = _kv_quantize(k[:, 0])
        vq, vs = _kv_quantize(v[:, 0])
        k_cache = k_cache.at[bidx, slot].set(kq)
        v_cache = v_cache.at[bidx, slot].set(vq)
        k_sc = cache["k_scale"].at[bidx, slot].set(ks)
        v_sc = cache["v_scale"].at[bidx, slot].set(vs)
        k_read = _kv_dequantize(k_cache, k_sc, x.dtype)
        v_read = _kv_dequantize(v_cache, v_sc, x.dtype)
    else:
        k_cache = k_cache.at[bidx, slot].set(k[:, 0])
        v_cache = v_cache.at[bidx, slot].set(v[:, 0])
        k_read, v_read = k_cache, v_cache
    slots = jnp.arange(Sc)[None, :]
    if mixer == "cla":
        # ring slot s holds absolute position chunk_start + s only when
        # s <= pos % window; later slots are stale previous-chunk entries
        valid = slots <= (pos % Sc)[:, None]
    else:
        # full (linear) and swa (ring): every written slot participates
        valid = slots <= pos[:, None]
    o = decode_attention(q, k_read, v_read, valid, logit_cap=cfg.attn_softcap)
    out = jnp.einsum("bshk,hkd->bsd", o, p[f"{prefix}.wo"].astype(x.dtype))
    new_cache = {"k": k_cache, "v": v_cache}
    if quant:
        new_cache.update({"k_scale": k_sc, "v_scale": v_sc})
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2)
# ---------------------------------------------------------------------------


def _mla_q(cfg, p, prefix, x, positions):
    from repro.models.layers import rmsnorm

    cq = jnp.einsum("bsd,dr->bsr", x, p[f"{prefix}.wq_a"].astype(x.dtype))
    cq = rmsnorm(cq, p[f"{prefix}.q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p[f"{prefix}.wq_b"].astype(x.dtype))
    q_nope = q[..., : cfg.nope_head_dim]
    q_rope = apply_rope(q[..., cfg.nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg, p, prefix, x, positions):
    from repro.models.layers import rmsnorm

    ckv = jnp.einsum("bsd,dr->bsr", x, p[f"{prefix}.wkv_a"].astype(x.dtype))
    c_kv = rmsnorm(ckv[..., : cfg.kv_lora_rank], p[f"{prefix}.kv_norm"])
    k_rope = apply_rope(
        ckv[..., None, cfg.kv_lora_rank :], positions, cfg.rope_theta
    )  # [B,S,1,rope_dim]
    return c_kv, k_rope


def mla_attn(cfg, p, prefix, x, positions, *, causal=True):
    """Training/prefill MLA (direct form). Returns (out, (c_kv, k_rope))."""
    B, S, D = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, p, prefix, x, positions)
    c_kv, k_rope = _mla_latent(cfg, p, prefix, x, positions)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p[f"{prefix}.wkv_b"].astype(x.dtype))
    k_nope = kv[..., : cfg.nope_head_dim]
    v = kv[..., cfg.nope_head_dim :]  # [B,S,H,v_hd]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, cfg.rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    o = chunked_attention(q, k, v, causal=causal)
    out = jnp.einsum("bshk,hkd->bsd", o, p[f"{prefix}.wo"].astype(x.dtype))
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(cfg, p, prefix, x, pos, cache):
    """Absorbed-matrix MLA decode over the compressed cache.

    score_h = q_nope_h . (W_uk_h c_kv) + q_rope_h . k_rope
            = (W_uk_h^T q_nope_h) . c_kv + q_rope_h . k_rope
    """
    B = x.shape[0]
    positions = pos[:, None]
    q_nope, q_rope = _mla_q(cfg, p, prefix, x, positions)  # [B,1,H,*]
    c_new, kr_new = _mla_latent(cfg, p, prefix, x, positions)
    ckv_cache, kr_cache = cache["c_kv"], cache["k_rope"]
    Sc = ckv_cache.shape[1]
    bidx = jnp.arange(B)
    ckv_cache = ckv_cache.at[bidx, pos].set(c_new[:, 0])
    kr_cache = kr_cache.at[bidx, pos].set(kr_new[:, 0, 0])

    wkv_b = p[f"{prefix}.wkv_b"].astype(x.dtype)  # [r,H,nope+v]
    w_uk = wkv_b[..., : cfg.nope_head_dim]  # [r,H,nope]
    w_uv = wkv_b[..., cfg.nope_head_dim :]  # [r,H,v_hd]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)  # absorbed q
    s = jnp.einsum("bhr,bsr->bhs", q_lat[:, 0], ckv_cache) + jnp.einsum(
        "bhk,bsk->bhs", q_rope[:, 0], kr_cache
    )
    scale = (cfg.nope_head_dim + cfg.rope_head_dim) ** -0.5
    s = s.astype(jnp.float32) * scale
    valid = jnp.arange(Sc)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, :], s, _NEG)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr, ckv_cache)
    o = jnp.einsum("bhr,rhk->bhk", o_lat, w_uv)  # [B,H,v_hd]
    out = jnp.einsum("bhk,hkd->bd", o, p[f"{prefix}.wo"].astype(x.dtype))[:, None]
    return out, {"c_kv": ckv_cache, "k_rope": kr_cache}


def cross_attn(cfg, p, prefix, x, enc_out):
    """Encoder-decoder cross attention (full, no RoPE on memory)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p[f"{prefix}.wq"].astype(x.dtype))
    k = jnp.einsum("bmd,dnk->bmnk", enc_out, p[f"{prefix}.wk"].astype(x.dtype))
    v = jnp.einsum("bmd,dnk->bmnk", enc_out, p[f"{prefix}.wv"].astype(x.dtype))
    o = chunked_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p[f"{prefix}.wo"].astype(x.dtype))