"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, sequential recurrence).

Training uses the paper's stabilized parallel form for mLSTM (query-chunked,
O(S * chunk) memory) and a lax.scan for sLSTM. Decode is the O(1) recurrent
update for both. d_ff = 0 for this family: the blocks carry their own
up/down projections (gated output), no separate FFN.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm


def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B,S,D], w: [W,D]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------


def mlstm_parallel(q, k, v, logi, logf, q_chunk: int = 512):
    """Stabilized parallel mLSTM (xLSTM paper eq. 19-27).

    q/k/v: [B,H,S,dh]; logi/logf: [B,H,S] (log input gate, log sigmoid forget).
    Returns h: [B,H,S,dh].
    """
    B, H, S, dh = q.shape
    scale = dh**-0.5
    F = jnp.cumsum(logf, axis=-1)  # [B,H,S]
    qc = min(q_chunk, S)
    n_chunks = S // qc

    def one_chunk(ci):
        q0 = ci * qc
        qi = jax.lax.dynamic_slice_in_dim(q, q0, qc, axis=2)
        Fi = jax.lax.dynamic_slice_in_dim(F, q0, qc, axis=2)  # [B,H,qc]
        # D~[i,j] = F_i - F_j + logi_j for j <= i
        Dt = Fi[..., :, None] - F[..., None, :] + logi[..., None, :]
        qpos = q0 + jnp.arange(qc)
        causal = jnp.arange(S)[None, :] <= qpos[:, None]
        Dt = jnp.where(causal, Dt, -jnp.inf)
        m = jnp.maximum(jnp.max(Dt, axis=-1), -1e30)  # [B,H,qc]
        D = jnp.exp(Dt - m[..., None])
        s = jnp.einsum("bhqd,bhkd->bhqk", qi, k) * scale
        Sm = s * D
        norm = jnp.maximum(jnp.abs(jnp.sum(Sm, axis=-1)), jnp.exp(-m))
        return jnp.einsum("bhqk,bhkd->bhqd", Sm / norm[..., None], v)

    if n_chunks <= 1:
        return one_chunk(0)
    outs = jax.lax.map(one_chunk, jnp.arange(n_chunks))  # [C,B,H,qc,dh]
    return jnp.moveaxis(outs, 0, 2).reshape(B, H, S, dh)


def mlstm_step(state, q, k, v, logi, logf):
    """O(1) decode update. state: dict(C [B,H,dk,dv], n [B,H,dk], m [B,H]).
    q/k/v: [B,H,dh]; logi/logf: [B,H]."""
    C, n, m = state["C"], state["n"], state["m"]
    dh = q.shape[-1]
    m_new = jnp.maximum(logf + m, logi)
    fa = jnp.exp(logf + m - m_new)[..., None]
    ia = jnp.exp(logi - m_new)[..., None]
    n_new = fa * n + ia * k
    C_new = fa[..., None] * C + (ia * k)[..., None] * v[..., None, :]
    qn = q * (dh**-0.5)
    num = jnp.einsum("bhk,bhkv->bhv", qn, C_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", qn, n_new)), jnp.exp(-m_new)
    )
    h = num / den[..., None]
    return {"C": C_new, "n": n_new, "m": m_new}, h


def mlstm_final_state(k, v, logi, logf):
    """Recurrent state (C, n, m) after consuming the whole sequence — used to
    seed the decode cache from a prefill. k/v: [B,H,S,dh]; gates [B,H,S]."""
    F = jnp.cumsum(logf, axis=-1)
    w_log = F[..., -1:] - F + logi  # [B,H,S]
    m = jnp.max(w_log, axis=-1)  # [B,H]
    w = jnp.exp(w_log - m[..., None])
    C = jnp.einsum("bhs,bhsk,bhsv->bhkv", w, k, v)
    n = jnp.einsum("bhs,bhsk->bhk", w, k)
    return {"C": C, "n": n, "m": m}


def mlstm_block(cfg, p, prefix, x, *, cache=None, return_state: bool = False):
    """Full mLSTM residual block. x: [B,S,D] (S=1 with cache).
    Returns (out, new_cache)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    xn = rmsnorm(x, p[f"{prefix}.ln"])
    u = jnp.einsum("bsd,de->bse", xn, p[f"{prefix}.wu"].astype(x.dtype))  # [B,S,2D]
    a, b = jnp.split(u, 2, axis=-1)
    if cache is None:
        c = causal_conv(a, p[f"{prefix}.conv"].astype(x.dtype))
        conv_cache = None
    else:
        buf = jnp.concatenate([cache["conv"], a], axis=1)  # [B,W,D]
        c = jnp.einsum("bwd,wd->bd", buf, p[f"{prefix}.conv"].astype(x.dtype))[:, None]
        conv_cache = buf[:, 1:]
    c = jax.nn.silu(c)
    q = jnp.einsum("bsd,de->bse", c, p[f"{prefix}.wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", c, p[f"{prefix}.wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", a, p[f"{prefix}.wv"].astype(x.dtype))
    gi = jnp.einsum("bsd,dh->bsh", xn, p[f"{prefix}.wi"].astype(x.dtype)) + p[
        f"{prefix}.bi"
    ].astype(x.dtype)
    gf = jnp.einsum("bsd,dh->bsh", xn, p[f"{prefix}.wf"].astype(x.dtype)) + p[
        f"{prefix}.bf"
    ].astype(x.dtype)
    logi = gi.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(gf.astype(jnp.float32))

    qh = q.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    kh = k.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    vh = v.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    if cache is None:
        kf = kh.astype(jnp.float32)
        vf = vh.astype(jnp.float32)
        li = logi.transpose(0, 2, 1)
        lf = logf.transpose(0, 2, 1)
        h = mlstm_parallel(qh.astype(jnp.float32), kf, vf, li, lf)
        new_cache = None
        if return_state:
            st = mlstm_final_state(kf, vf, li, lf)
            new_cache = {
                "state": st,
                "conv": a[:, -(p[f"{prefix}.conv"].shape[0] - 1) :, :],
            }
    else:
        st, h1 = mlstm_step(
            cache["state"],
            qh[:, :, 0].astype(jnp.float32),
            kh[:, :, 0].astype(jnp.float32),
            vh[:, :, 0].astype(jnp.float32),
            logi[:, 0],
            logf[:, 0],
        )
        h = h1[:, :, None, :]
        new_cache = {"state": st, "conv": conv_cache}
    hs = h.transpose(0, 2, 1, 3).reshape(B, S, D).astype(x.dtype)
    hs = rmsnorm(hs, p[f"{prefix}.mn"])  # per-head norm approximated group-wise
    out = hs * jax.nn.silu(b)
    return jnp.einsum("bse,ed->bsd", out, p[f"{prefix}.wd"].astype(x.dtype)), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_block(cfg, p, prefix, x, *, cache=None, return_state: bool = False):
    """sLSTM residual block with per-head block-diagonal recurrence.
    Training: lax.scan over time. Decode: single step."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    xn = rmsnorm(x, p[f"{prefix}.ln"])
    # input contributions for the 4 gates: [B,S,4D]
    zx = jnp.einsum("bsd,de->bse", xn, p[f"{prefix}.wzifo"].astype(x.dtype)) + p[
        f"{prefix}.bzifo"
    ].astype(x.dtype)
    r = p[f"{prefix}.r"].astype(jnp.float32)  # [4,H,dh,dh] recurrent per head

    def step(carry, zt):
        c, n, m, h = carry  # [B,H,dh] each, fp32
        rec = jnp.einsum("bhk,ghkl->bghl", h, r)  # [B,4,H,dh]
        zt = zt.astype(jnp.float32).reshape(B, 4, H, dh) + rec
        z, i, f, o = zt[:, 0], zt[:, 1], zt[:, 2], zt[:, 3]
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        logf = jax.nn.log_sigmoid(f)
        m_new = jnp.maximum(logf + m, i)
        ia = jnp.exp(i - m_new)
        fa = jnp.exp(logf + m - m_new)
        c_new = fa * c + ia * z
        n_new = jnp.maximum(fa * n + ia, jnp.exp(-m_new))
        h_new = o * (c_new / n_new)
        return (c_new, n_new, m_new, h_new), h_new

    if cache is None:
        z0 = jnp.zeros((B, H, dh), jnp.float32)
        carry0 = (z0, jnp.ones_like(z0), jnp.zeros_like(z0), z0)
        carry, hs = jax.lax.scan(step, carry0, zx.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
        new_cache = (
            {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
            if return_state
            else None
        )
    else:
        carry = (cache["c"], cache["n"], cache["m"], cache["h"])
        carry, h1 = step(carry, zx[:, 0])
        hs = h1.reshape(B, 1, D).astype(x.dtype)
        new_cache = {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
    hs = rmsnorm(hs, p[f"{prefix}.mn"])
    return jnp.einsum("bse,ed->bsd", hs, p[f"{prefix}.wd"].astype(x.dtype)), new_cache