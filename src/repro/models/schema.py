"""Parameter schema: one declarative source of truth for shapes, logical
sharding axes and initialization of every weight.

A schema is a flat dict  name -> ParamSpec(shape, axes, init, dtype) .
From it we derive, without ever materializing weights:
  * abstract_params(schema)      — ShapeDtypeStruct tree (for .lower())
  * shardings(schema, rules, mesh) — NamedSharding tree (logical->mesh axes)
  * init_params(schema, key)     — real arrays (smoke tests / real training)

Logical axis vocabulary (MaxText-style):
  "layers"  — stacked-layer dim (scanned over; never sharded)
  "embed"   — d_model            (FSDP axis: sharded over "data" for storage)
  "vocab"   — vocabulary         (sharded over "model")
  "heads"   — attention heads    (sharded over "model")
  "kv"      — kv heads           (replicated or "model" when divisible)
  "mlp"     — feed-forward dim   (sharded over "model")
  "experts" — MoE experts        (sharded over "model" = expert parallelism)
  "state"/"conv"/None — small dims, replicated
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | embed | scaled:<fanin-dim>
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = dict  # name -> ParamSpec


def abstract_params(schema: Schema) -> dict:
    return {
        n: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)) for n, s in schema.items()
    }


def logical_to_spec(axes: tuple, rules: dict) -> P:
    mesh_axes = []
    used = set()
    for ax in axes:
        m = rules.get(ax)
        # one mesh axis can shard at most one dim of a tensor
        if m is None or m in used:
            mesh_axes.append(None)
        else:
            mesh_axes.append(m)
            used.add(m if isinstance(m, str) else tuple(m))
    return P(*mesh_axes)


def shardings(schema: Schema, rules: dict, mesh: Mesh) -> dict:
    out = {}
    for n, s in schema.items():
        spec = logical_to_spec(s.axes, rules)
        # drop mesh axes that do not divide the dim (GSPMD would pad; we prefer
        # replication for oddball dims like kv=8 on a 16-way axis)
        fixed = []
        for dim, m in zip(s.shape, spec):
            if m is None:
                fixed.append(None)
                continue
            size = (
                mesh.shape[m]
                if isinstance(m, str)
                else math.prod(mesh.shape[a] for a in m)
            )
            fixed.append(m if dim % size == 0 else None)
        out[n] = NamedSharding(mesh, P(*fixed))
    return out


def init_params(schema: Schema, key: jax.Array, dtype=None) -> dict:
    params = {}
    names = sorted(schema.keys())
    keys = jax.random.split(key, len(names))
    for k, n in zip(keys, names):
        s = schema[n]
        dt = jnp.dtype(dtype or s.dtype)
        if s.init == "zeros":
            params[n] = jnp.zeros(s.shape, dt)
        elif s.init == "ones":
            params[n] = jnp.ones(s.shape, dt)
        elif s.init == "embed":
            params[n] = (jax.random.normal(k, s.shape, dt) * 0.02).astype(dt)
        elif s.init.startswith("scaled"):
            fan_in = int(s.init.split(":")[1]) if ":" in s.init else s.shape[-2]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            params[n] = (jax.random.normal(k, s.shape, dt) * std).astype(dt)
        else:  # normal
            params[n] = (jax.random.normal(k, s.shape, dt) * 0.02).astype(dt)
    return params


def param_count(schema: Schema) -> int:
    return sum(math.prod(s.shape) for s in schema.values())


def param_bytes(schema: Schema) -> int:
    return sum(
        math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in schema.values()
    )
