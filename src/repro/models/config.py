"""Unified model configuration covering all ten assigned architectures.

A model is a stack of `n_layers` blocks. Blocks repeat with period
`len(pattern)`; each pattern entry names a (mixer, ffn) pair:

  mixer: "gqa"   — grouped-query attention (optional QKV bias, RoPE)
         "swa"   — sliding-window GQA
         "cla"   — chunked local attention (Llama-4 iRoPE style)
         "mla"   — multi-head latent attention (MiniCPM3 / DeepSeek-V2)
         "mlstm" — xLSTM matrix-memory block
         "slstm" — xLSTM scalar-memory block
         "rglru" — RG-LRU temporal block (Griffin / RecurrentGemma)
  ffn:   "dense" | "moe" | "none" (xLSTM blocks integrate their own proj)

Encoder-decoder models (seamless-m4t) set `n_enc_layers` > 0; the decoder
adds cross-attention to every block. Modality frontends ("audio"/"vision")
are STUBS per the assignment: input_specs() feeds precomputed frame/patch
embeddings of `frontend_dim`.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 => d_model // n_heads
    pattern: tuple = (("gqa", "dense"),)
    tail: tuple = ()  # extra layers after the scanned groups (n_layers % period)
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int = 4096  # swa/cla window or chunk
    norm: str = "rmsnorm"
    act: str = "silu"
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # MLA dims (MiniCPM3-4B defaults)
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    rope_head_dim: int = 32
    nope_head_dim: int = 64
    v_head_dim: int = 0  # 0 => nope + rope
    # recurrent dims
    rglru_conv_width: int = 4
    rnn_scale: float = 1.0  # recurrent block width multiplier
    # encoder-decoder / frontends
    n_enc_layers: int = 0
    frontend: str = "none"  # none | audio | vision
    frontend_dim: int = 0
    # serving
    max_seq: int = 32768
    kv_cache_dtype: str = "bf16"  # "bf16" | "int8" (quantized cache, §Perf)
    # attention softcap (recurrentgemma uses logit softcapping)
    attn_softcap: float = 0.0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def v_hd(self) -> int:
        if self.v_head_dim:
            return self.v_head_dim
        if self.has_mla:
            return self.nope_head_dim
        return self.hd

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_groups(self) -> int:
        rem = self.n_layers - len(self.tail)
        assert rem % self.period == 0, (self.n_layers, self.period, len(self.tail))
        return rem // self.period

    @property
    def has_mla(self) -> bool:
        return any(m == "mla" for m, _ in self.pattern)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Every mixer is windowed/chunked or recurrent (bounded state)."""
        return all(
            m in ("swa", "cla", "mlstm", "slstm", "rglru")
            for m, _ in tuple(self.pattern) + tuple(self.tail)
        )

    @property
    def long_context_capable(self) -> bool:
        """long_500k runs unless the arch is *pure* full attention (per the
        assignment: run for SSM/hybrid/linear-attn, skip pure-quadratic)."""
        return any(
            m in ("swa", "cla", "mlstm", "slstm", "rglru")
            for m, _ in tuple(self.pattern) + tuple(self.tail)
        )

    def params_dense(self) -> int:
        """Approximate parameter count N for MODEL_FLOPS = 6*N*D."""
        from repro.models.stack import build_schema
        from repro.models.schema import param_count

        return param_count(build_schema(self))

    def params_active(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        n = self.params_dense()
        if self.n_experts > 0:
            moe_layers = sum(1 for _, f in self.pattern if f == "moe") * self.n_groups
            per_expert = 3 * self.d_model * self.d_ff
            n -= moe_layers * per_expert * (self.n_experts - self.top_k)
        return n


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch x input-shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)
