"""Model stack: declarative parameter schema + forward passes.

Layers are stacked by pattern *group*: a config with pattern period P and
n_layers = G*P (+ tail) stores each pattern slot's weights as [G, ...] arrays
and scans over G (jax.lax.scan) — the HLO stays one-group-sized, which is what
keeps 80-layer × 512-device lowering fast.

Three entry points (all pure):
  forward_train(cfg, params, batch)             -> logits
  forward_prefill(cfg, params, batch)           -> (last_logits, cache)
  forward_decode(cfg, params, token, pos, cache)-> (logits, new_cache)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import rglru as rg
from repro.models import xlstm as xl
from repro.models.config import ModelConfig
from repro.models.layers import dense_ffn, embed_lookup, ffn, norm, rmsnorm
from repro.models.schema import ParamSpec, Schema

# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------


def _attn_schema(cfg: ModelConfig, pfx: str) -> Schema:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        f"{pfx}.ln": ParamSpec((D,), ("embed",), "zeros"),
        f"{pfx}.wq": ParamSpec((D, H, hd), ("embed", "heads", None), f"scaled:{D}"),
        f"{pfx}.wk": ParamSpec((D, KV, hd), ("embed", "kv", None), f"scaled:{D}"),
        f"{pfx}.wv": ParamSpec((D, KV, hd), ("embed", "kv", None), f"scaled:{D}"),
        f"{pfx}.wo": ParamSpec((H, hd, D), ("heads", None, "embed"), f"scaled:{H*hd}"),
    }
    if cfg.qkv_bias:
        s[f"{pfx}.bq"] = ParamSpec((H, hd), ("heads", None), "zeros")
        s[f"{pfx}.bk"] = ParamSpec((KV, hd), ("kv", None), "zeros")
        s[f"{pfx}.bv"] = ParamSpec((KV, hd), ("kv", None), "zeros")
    return s


def _mla_schema(cfg: ModelConfig, pfx: str) -> Schema:
    D, H = cfg.d_model, cfg.n_heads
    qk = cfg.nope_head_dim + cfg.rope_head_dim
    return {
        f"{pfx}.ln": ParamSpec((D,), ("embed",), "zeros"),
        f"{pfx}.wq_a": ParamSpec((D, cfg.q_lora_rank), ("embed", None), f"scaled:{D}"),
        f"{pfx}.q_norm": ParamSpec((cfg.q_lora_rank,), (None,), "zeros"),
        f"{pfx}.wq_b": ParamSpec(
            (cfg.q_lora_rank, H, qk), (None, "heads", None), f"scaled:{cfg.q_lora_rank}"
        ),
        f"{pfx}.wkv_a": ParamSpec(
            (D, cfg.kv_lora_rank + cfg.rope_head_dim), ("embed", None), f"scaled:{D}"
        ),
        f"{pfx}.kv_norm": ParamSpec((cfg.kv_lora_rank,), (None,), "zeros"),
        f"{pfx}.wkv_b": ParamSpec(
            (cfg.kv_lora_rank, H, cfg.nope_head_dim + cfg.v_hd),
            (None, "heads", None),
            f"scaled:{cfg.kv_lora_rank}",
        ),
        f"{pfx}.wo": ParamSpec(
            (H, cfg.v_hd, D), ("heads", None, "embed"), f"scaled:{H*cfg.v_hd}"
        ),
    }


def _mlstm_schema(cfg: ModelConfig, pfx: str) -> Schema:
    D, H = cfg.d_model, cfg.n_heads
    return {
        f"{pfx}.ln": ParamSpec((D,), ("embed",), "zeros"),
        f"{pfx}.wu": ParamSpec((D, 2 * D), ("embed", "mlp"), f"scaled:{D}"),
        f"{pfx}.conv": ParamSpec((4, D), (None, None), f"scaled:4"),
        f"{pfx}.wq": ParamSpec((D, D), ("embed", "mlp"), f"scaled:{D}"),
        f"{pfx}.wk": ParamSpec((D, D), ("embed", "mlp"), f"scaled:{D}"),
        f"{pfx}.wv": ParamSpec((D, D), ("embed", "mlp"), f"scaled:{D}"),
        f"{pfx}.wi": ParamSpec((D, H), ("embed", None), f"scaled:{D}"),
        f"{pfx}.wf": ParamSpec((D, H), ("embed", None), f"scaled:{D}"),
        f"{pfx}.bi": ParamSpec((H,), (None,), "zeros"),
        f"{pfx}.bf": ParamSpec((H,), (None,), "ones"),
        f"{pfx}.mn": ParamSpec((D,), ("embed",), "zeros"),
        f"{pfx}.wd": ParamSpec((D, D), ("mlp", "embed"), f"scaled:{D}"),
    }


def _slstm_schema(cfg: ModelConfig, pfx: str) -> Schema:
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    return {
        f"{pfx}.ln": ParamSpec((D,), ("embed",), "zeros"),
        f"{pfx}.wzifo": ParamSpec((D, 4 * D), ("embed", "mlp"), f"scaled:{D}"),
        f"{pfx}.bzifo": ParamSpec((4 * D,), ("mlp",), "zeros"),
        f"{pfx}.r": ParamSpec(
            (4, H, dh, dh), (None, "heads", None, None), f"scaled:{dh}"
        ),
        f"{pfx}.mn": ParamSpec((D,), ("embed",), "zeros"),
        f"{pfx}.wd": ParamSpec((D, D), ("mlp", "embed"), f"scaled:{D}"),
    }


def _rglru_schema(cfg: ModelConfig, pfx: str) -> Schema:
    D = cfg.d_model
    E = int(cfg.rnn_scale * D)
    return {
        f"{pfx}.ln": ParamSpec((D,), ("embed",), "zeros"),
        f"{pfx}.wgate": ParamSpec((D, E), ("embed", "mlp"), f"scaled:{D}"),
        f"{pfx}.wx": ParamSpec((D, E), ("embed", "mlp"), f"scaled:{D}"),
        f"{pfx}.conv": ParamSpec((cfg.rglru_conv_width, E), (None, "mlp"), "scaled:4"),
        f"{pfx}.wa": ParamSpec((E, E), ("embed", "mlp"), f"scaled:{E}"),
        f"{pfx}.wi": ParamSpec((E, E), ("embed", "mlp"), f"scaled:{E}"),
        f"{pfx}.ba": ParamSpec((E,), ("mlp",), "ones"),
        f"{pfx}.bi": ParamSpec((E,), ("mlp",), "zeros"),
        f"{pfx}.lam": ParamSpec((E,), ("mlp",), "ones"),
        f"{pfx}.wout": ParamSpec((E, D), ("mlp", "embed"), f"scaled:{E}"),
    }


def _ffn_schema(cfg: ModelConfig, pfx: str, kind: str) -> Schema:
    D, F = cfg.d_model, cfg.d_ff
    if kind == "none":
        return {}
    if kind == "moe":
        E = cfg.n_experts
        return {
            f"{pfx}.ln2": ParamSpec((D,), ("embed",), "zeros"),
            f"{pfx}.router": ParamSpec((D, E), ("embed", None), f"scaled:{D}"),
            f"{pfx}.we_g": ParamSpec(
                (E, D, F), ("experts", "embed", "mlp"), f"scaled:{D}"
            ),
            f"{pfx}.we_u": ParamSpec(
                (E, D, F), ("experts", "embed", "mlp"), f"scaled:{D}"
            ),
            f"{pfx}.we_d": ParamSpec(
                (E, F, D), ("experts", "mlp", "embed"), f"scaled:{F}"
            ),
        }
    return {
        f"{pfx}.ln2": ParamSpec((D,), ("embed",), "zeros"),
        f"{pfx}.wg": ParamSpec((D, F), ("embed", "mlp"), f"scaled:{D}"),
        f"{pfx}.wu": ParamSpec((D, F), ("embed", "mlp"), f"scaled:{D}"),
        f"{pfx}.wd": ParamSpec((F, D), ("mlp", "embed"), f"scaled:{F}"),
    }


_MIXER_SCHEMA = {
    "gqa": _attn_schema,
    "swa": _attn_schema,
    "cla": _attn_schema,
    "mla": _mla_schema,
    "mlstm": _mlstm_schema,
    "slstm": _slstm_schema,
    "rglru": _rglru_schema,
}


def _layer_schema(cfg: ModelConfig, pfx: str, mixer: str, ffn_kind: str, cross: bool) -> Schema:
    s = dict(_MIXER_SCHEMA[mixer](cfg, f"{pfx}.mix"))
    s.update(_ffn_schema(cfg, f"{pfx}.ffn", ffn_kind))
    if cross:
        s.update(_attn_schema(cfg, f"{pfx}.x"))
        # cross-attention has no qkv bias regardless of cfg
        for b in (f"{pfx}.x.bq", f"{pfx}.x.bk", f"{pfx}.x.bv"):
            s.pop(b, None)
    return s


def _stack(s: Schema, g: int) -> Schema:
    return {
        n: ParamSpec((g,) + sp.shape, ("layers",) + sp.axes, sp.init, sp.dtype)
        for n, sp in s.items()
    }


def tail_layers(cfg: ModelConfig) -> tuple:
    tail = getattr(cfg, "tail", ())
    return tuple(tail)


def n_groups(cfg: ModelConfig) -> int:
    tail = tail_layers(cfg)
    assert (cfg.n_layers - len(tail)) % cfg.period == 0
    return (cfg.n_layers - len(tail)) // cfg.period


def build_schema(cfg: ModelConfig) -> Schema:
    s: Schema = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "final_ln": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec(
            (cfg.d_model, cfg.vocab), ("embed", "vocab"), f"scaled:{cfg.d_model}"
        )
    if cfg.frontend != "none":
        s["frontend_proj"] = ParamSpec(
            (cfg.frontend_dim, cfg.d_model), (None, "embed"), f"scaled:{cfg.frontend_dim}"
        )
    G = n_groups(cfg)
    cross = cfg.is_encdec
    for j, (mixer, fk) in enumerate(cfg.pattern):
        s.update(_stack(_layer_schema(cfg, f"blk{j}", mixer, fk, cross), G))
    for i, (mixer, fk) in enumerate(tail_layers(cfg)):
        s.update(_layer_schema(cfg, f"tail{i}", mixer, fk, cross))
    if cfg.is_encdec:
        enc = _layer_schema(cfg, "eblk0", "gqa", "dense", False)
        s.update(_stack(enc, cfg.n_enc_layers))
        s["enc_final_ln"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _slice_group(params: dict, pfx: str) -> dict:
    return {k: v for k, v in params.items() if k.startswith(pfx + ".")}


def _apply_mixer(cfg, p, pfx, mixer, x, positions, causal=True):
    """Train/prefill mixer application. Returns (y, cache_seed)."""
    xn = rmsnorm(x, p[f"{pfx}.ln"])
    if mixer in ("gqa", "swa", "cla"):
        y, kv = attn.gqa_attn(cfg, p, pfx, xn, positions, mixer=mixer, causal=causal)
        return y, ("kv", kv)
    if mixer == "mla":
        y, ckr = attn.mla_attn(cfg, p, pfx, xn, positions, causal=causal)
        return y, ("mla", ckr)
    if mixer == "mlstm":
        y, _ = xl.mlstm_block(cfg, {k.replace(pfx, pfx): v for k, v in p.items()}, pfx, x)
        return y, ("mlstm", None)
    if mixer == "slstm":
        y, _ = xl.slstm_block(cfg, p, pfx, x)
        return y, ("slstm", None)
    if mixer == "rglru":
        y, _ = rg.rglru_block(cfg, p, pfx, x)
        return y, ("rglru", None)
    raise ValueError(mixer)


def _apply_layer(cfg, p, pfx, mixer, fk, x, positions, enc_out=None, causal=True):
    if mixer in ("mlstm", "slstm", "rglru"):
        # these blocks norm internally and include their own projections
        y, seed = _apply_mixer(cfg, p, pfx + ".mix", mixer, x, positions, causal)
        x = x + y
    else:
        y, seed = _apply_mixer(cfg, p, pfx + ".mix", mixer, x, positions, causal)
        x = x + y
    if enc_out is not None:
        xn = rmsnorm(x, p[f"{pfx}.x.ln"])
        x = x + attn.cross_attn(cfg, p, f"{pfx}.x", xn, enc_out)
    if fk != "none":
        xn = rmsnorm(x, p[f"{pfx}.ffn.ln2"])
        x = x + ffn(cfg, p, f"{pfx}.ffn", fk, xn)
    return x, seed


def _embed_inputs(cfg, params, batch):
    """Token (and stub-frontend) embedding. Returns (x, positions)."""
    dt = jnp.bfloat16
    if cfg.frontend == "vision":
        emb = jnp.einsum(
            "bpf,fd->bpd", batch["patches"].astype(dt), params["frontend_proj"].astype(dt)
        )
        tok = embed_lookup(params["embed"], batch["tokens"], dt)
        x = jnp.concatenate([emb, tok], axis=1)
    elif cfg.frontend == "audio" and "frames" in batch:
        x = jnp.einsum(
            "bsf,fd->bsd", batch["frames"].astype(dt), params["frontend_proj"].astype(dt)
        )
    else:
        x = embed_lookup(params["embed"], batch["tokens"], dt)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def _run_encoder(cfg, params, batch):
    x, positions = (
        _embed_inputs(cfg, params, {"frames": batch["frames"]})
        if cfg.frontend == "audio"
        else _embed_inputs(cfg, params, batch)
    )
    stacked = _slice_group(params, "eblk0")

    def body(h, layer_p):
        h, _ = _apply_layer(cfg, layer_p, "eblk0", "gqa", "dense", h, positions, causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, stacked)
    return rmsnorm(x, params["enc_final_ln"])


def forward_train(
    cfg: ModelConfig, params: dict, batch: dict, remat=False
) -> jax.Array:
    """Full-sequence forward -> logits [B, S, V].

    remat: False/"none" — no checkpointing; True/"full" — checkpoint each
    scanned layer group; "dots" — save matmul outputs, recompute elementwise
    only (jax.checkpoint_policies.dots_with_no_batch_dims_saveable)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = _run_encoder(cfg, params, batch)
        x, positions = _embed_inputs(cfg, params, {"tokens": batch["dec_tokens"]})
    else:
        x, positions = _embed_inputs(cfg, params, batch)

    G = n_groups(cfg)
    if G > 0:
        stacked = {}
        for j in range(len(cfg.pattern)):
            stacked.update(_slice_group(params, f"blk{j}"))

        def body(h, layer_p):
            for j, (mixer, fk) in enumerate(cfg.pattern):
                sub = {k: v for k, v in layer_p.items() if k.startswith(f"blk{j}.")}
                h, _ = _apply_layer(cfg, sub, f"blk{j}", mixer, fk, h, positions, enc_out)
            return h, None

        if remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                prevent_cse=False,
            )
        elif remat and remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, stacked)
    for i, (mixer, fk) in enumerate(tail_layers(cfg)):
        sub = _slice_group(params, f"tail{i}")
        x, _ = _apply_layer(cfg, sub, f"tail{i}", mixer, fk, x, positions, enc_out)

    x = rmsnorm(x, params["final_ln"])
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    return jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))


# ---------------------------------------------------------------------------
# prefill / decode (KV + recurrent-state caches)
# ---------------------------------------------------------------------------


def _ring_fill(k: jax.Array, cap: int) -> jax.Array:
    """Pack the last `cap` timesteps of k [B,S,...] into a ring buffer of
    capacity `cap` laid out by absolute-position % cap."""
    B, S = k.shape[:2]
    w = min(cap, S)
    tail = k[:, S - w :]
    slots = (jnp.arange(S - w, S)) % cap
    buf = jnp.zeros((B, cap) + k.shape[2:], k.dtype)
    return buf.at[:, slots].set(tail)


def _cache_capacity(cfg: ModelConfig, mixer: str, cache_len: int) -> int:
    if mixer in ("swa", "cla"):
        return min(cfg.window, cache_len)
    return cache_len


def _seed_to_cache(cfg, mixer, seed, cache_len):
    kind, data = seed
    if kind == "kv":
        k, v = data
        cap = _cache_capacity(cfg, mixer, cache_len)
        quant = cfg.kv_cache_dtype == "int8"
        if quant:
            from repro.models.attention import _kv_quantize
            import jax as _jax

            kq, ks = _jax.vmap(_kv_quantize, in_axes=1, out_axes=1)(k)
            vq, vs = _jax.vmap(_kv_quantize, in_axes=1, out_axes=1)(v)
            if cap == cache_len:
                pad = cache_len - k.shape[1]
                out = {
                    "k": jnp.pad(kq, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "v": jnp.pad(vq, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "k_scale": jnp.pad(ks, ((0, 0), (0, pad), (0, 0))),
                    "v_scale": jnp.pad(vs, ((0, 0), (0, pad), (0, 0))),
                }
            else:
                out = {
                    "k": _ring_fill(kq, cap),
                    "v": _ring_fill(vq, cap),
                    "k_scale": _ring_fill(ks, cap),
                    "v_scale": _ring_fill(vs, cap),
                }
            return out
        if cap == cache_len:  # linear cache, pad to capacity
            pad = cache_len - k.shape[1]
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return {"k": k, "v": v}
        return {"k": _ring_fill(k, cap), "v": _ring_fill(v, cap)}
    if kind == "mla":
        c_kv, k_rope = data
        pad = cache_len - c_kv.shape[1]
        return {
            "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
            "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
        }
    return data  # recurrent states are already decode-shaped


def _prefill_layer(cfg, p, pfx, mixer, fk, x, positions, cache_len, enc_out=None):
    if mixer in ("mlstm", "slstm", "rglru"):
        fn = {"mlstm": xl.mlstm_block, "slstm": xl.slstm_block, "rglru": rg.rglru_block}[
            mixer
        ]
        y, cache = fn(cfg, p, pfx + ".mix", x, return_state=True)
        x = x + y
    else:
        xn = rmsnorm(x, p[f"{pfx}.mix.ln"])
        if mixer == "mla":
            y, seed = attn.mla_attn(cfg, p, pfx + ".mix", xn, positions)
            cache = _seed_to_cache(cfg, mixer, ("mla", seed), cache_len)
        else:
            y, kv = attn.gqa_attn(cfg, p, pfx + ".mix", xn, positions, mixer=mixer)
            cache = _seed_to_cache(cfg, mixer, ("kv", kv), cache_len)
        x = x + y
    if enc_out is not None:
        xn = rmsnorm(x, p[f"{pfx}.x.ln"])
        x = x + attn.cross_attn(cfg, p, f"{pfx}.x", xn, enc_out)
        # cross K/V are position-independent: cache them once
        dt = x.dtype
        xk = jnp.einsum("bmd,dnk->bmnk", enc_out, p[f"{pfx}.x.wk"].astype(dt))
        xv = jnp.einsum("bmd,dnk->bmnk", enc_out, p[f"{pfx}.x.wv"].astype(dt))
        cache = {"self": cache, "xk": xk, "xv": xv}
    if fk != "none":
        xn = rmsnorm(x, p[f"{pfx}.ffn.ln2"])
        x = x + ffn(cfg, p, f"{pfx}.ffn", fk, xn)
    return x, cache


def forward_prefill(cfg: ModelConfig, params: dict, batch: dict, cache_len: int):
    """Prefill: full forward + decode-ready cache. Returns (last_logits, cache)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = _run_encoder(cfg, params, batch)
        x, positions = _embed_inputs(cfg, params, {"tokens": batch["dec_tokens"]})
    else:
        x, positions = _embed_inputs(cfg, params, batch)

    cache = {}
    G = n_groups(cfg)
    if G > 0:
        stacked = {}
        for j in range(len(cfg.pattern)):
            stacked.update(_slice_group(params, f"blk{j}"))

        def body(h, layer_p):
            caches = {}
            for j, (mixer, fk) in enumerate(cfg.pattern):
                sub = {k: v for k, v in layer_p.items() if k.startswith(f"blk{j}.")}
                h, c = _prefill_layer(
                    cfg, sub, f"blk{j}", mixer, fk, h, positions, cache_len, enc_out
                )
                caches[f"blk{j}"] = c
            return h, caches

        x, scan_caches = jax.lax.scan(body, x, stacked)
        cache.update(scan_caches)
    for i, (mixer, fk) in enumerate(tail_layers(cfg)):
        sub = _slice_group(params, f"tail{i}")
        x, c = _prefill_layer(
            cfg, sub, f"tail{i}", mixer, fk, x, positions, cache_len, enc_out
        )
        cache[f"tail{i}"] = c

    x = rmsnorm(x, params["final_ln"])
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(x.dtype))
    return logits, cache


def _decode_layer(cfg, p, pfx, mixer, fk, x, pos, cache):
    xcache = None
    if isinstance(cache, dict) and "self" in cache:
        xcache, cache = cache, cache["self"]
    if mixer in ("mlstm", "slstm", "rglru"):
        fn = {"mlstm": xl.mlstm_block, "slstm": xl.slstm_block, "rglru": rg.rglru_block}[
            mixer
        ]
        y, new_c = fn(cfg, p, pfx + ".mix", x, cache=cache)
        x = x + y
    else:
        xn = rmsnorm(x, p[f"{pfx}.mix.ln"])
        if mixer == "mla":
            y, new_c = attn.mla_decode(cfg, p, pfx + ".mix", xn, pos, cache)
        else:
            y, new_c = attn.gqa_decode(cfg, p, pfx + ".mix", xn, pos, cache, mixer=mixer)
        x = x + y
    if xcache is not None:
        xn = rmsnorm(x, p[f"{pfx}.x.ln"])
        q = jnp.einsum("bsd,dhk->bshk", xn, p[f"{pfx}.x.wq"].astype(x.dtype))
        o = attn.decode_attention(
            q,
            xcache["xk"],
            xcache["xv"],
            jnp.ones((x.shape[0], xcache["xk"].shape[1]), bool),
        )
        x = x + jnp.einsum("bshk,hkd->bsd", o, p[f"{pfx}.x.wo"].astype(x.dtype))
        new_c = {"self": new_c, "xk": xcache["xk"], "xv": xcache["xv"]}
    if fk != "none":
        xn = rmsnorm(x, p[f"{pfx}.ffn.ln2"])
        x = x + ffn(cfg, p, f"{pfx}.ffn", fk, xn)
    return x, new_c


def forward_decode(cfg: ModelConfig, params: dict, token: jax.Array, pos: jax.Array, cache: dict):
    """One decode step. token/pos: [B]. Returns (logits [B,V], new_cache)."""
    x = embed_lookup(params["embed"], token, jnp.bfloat16)[:, None]  # [B,1,D]

    new_cache = {}
    G = n_groups(cfg)
    if G > 0:
        stacked = {}
        for j in range(len(cfg.pattern)):
            stacked.update(_slice_group(params, f"blk{j}"))
        blk_cache = {k: v for k, v in cache.items() if k.startswith("blk")}

        def body(h, xs):
            layer_p, layer_c = xs
            new_cs = {}
            for j, (mixer, fk) in enumerate(cfg.pattern):
                sub = {k: v for k, v in layer_p.items() if k.startswith(f"blk{j}.")}
                h, c = _decode_layer(cfg, sub, f"blk{j}", mixer, fk, h, pos, layer_c[f"blk{j}"])
                new_cs[f"blk{j}"] = c
            return h, new_cs

        x, scan_caches = jax.lax.scan(body, x, (stacked, blk_cache))
        new_cache.update(scan_caches)
    for i, (mixer, fk) in enumerate(tail_layers(cfg)):
        sub = _slice_group(params, f"tail{i}")
        x, c = _decode_layer(cfg, sub, f"tail{i}", mixer, fk, x, pos, cache[f"tail{i}"])
        new_cache[f"tail{i}"] = c

    x = rmsnorm(x, params["final_ln"])
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bd,dv->bv", x[:, 0], head.astype(x.dtype))
    return logits, new_cache


# ---------------------------------------------------------------------------
# cache specs (abstract, for the dry-run) and zero-init (for real serving)
# ---------------------------------------------------------------------------


def _layer_cache_spec(cfg: ModelConfig, mixer: str, B: int, cache_len: int, dt=jnp.bfloat16):
    KV, hd = cfg.n_kv_heads, cfg.hd
    H, D = cfg.n_heads, cfg.d_model
    f32 = jnp.float32
    if mixer in ("gqa", "swa", "cla"):
        cap = _cache_capacity(cfg, mixer, cache_len)
        if cfg.kv_cache_dtype == "int8":
            return {
                "k": jax.ShapeDtypeStruct((B, cap, KV, hd), jnp.int8),
                "v": jax.ShapeDtypeStruct((B, cap, KV, hd), jnp.int8),
                "k_scale": jax.ShapeDtypeStruct((B, cap, KV), f32),
                "v_scale": jax.ShapeDtypeStruct((B, cap, KV), f32),
            }
        return {
            "k": jax.ShapeDtypeStruct((B, cap, KV, hd), dt),
            "v": jax.ShapeDtypeStruct((B, cap, KV, hd), dt),
        }
    if mixer == "mla":
        return {
            "c_kv": jax.ShapeDtypeStruct((B, cache_len, cfg.kv_lora_rank), dt),
            "k_rope": jax.ShapeDtypeStruct((B, cache_len, cfg.rope_head_dim), dt),
        }
    if mixer == "mlstm":
        dh = D // H
        return {
            "state": {
                "C": jax.ShapeDtypeStruct((B, H, dh, dh), f32),
                "n": jax.ShapeDtypeStruct((B, H, dh), f32),
                "m": jax.ShapeDtypeStruct((B, H), f32),
            },
            "conv": jax.ShapeDtypeStruct((B, 3, D), dt),
        }
    if mixer == "slstm":
        dh = D // H
        v = jax.ShapeDtypeStruct((B, H, dh), f32)
        return {"c": v, "n": v, "m": v, "h": v}
    if mixer == "rglru":
        E = int(cfg.rnn_scale * cfg.d_model)
        return {
            "h": jax.ShapeDtypeStruct((B, E), f32),
            "conv": jax.ShapeDtypeStruct((B, cfg.rglru_conv_width - 1, E), dt),
        }
    raise ValueError(mixer)


def decode_cache_specs(cfg: ModelConfig, B: int, cache_len: int, enc_len: int = 0):
    G = n_groups(cfg)
    cache = {}
    for j, (mixer, fk) in enumerate(cfg.pattern):
        spec = _layer_cache_spec(cfg, mixer, B, cache_len)
        if cfg.is_encdec:
            spec = {
                "self": spec,
                "xk": jax.ShapeDtypeStruct((B, enc_len, cfg.n_kv_heads, cfg.hd), jnp.bfloat16),
                "xv": jax.ShapeDtypeStruct((B, enc_len, cfg.n_kv_heads, cfg.hd), jnp.bfloat16),
            }
        if G > 0:
            spec = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((G,) + s.shape, s.dtype), spec
            )
        cache[f"blk{j}"] = spec
    for i, (mixer, fk) in enumerate(tail_layers(cfg)):
        spec = _layer_cache_spec(cfg, mixer, B, cache_len)
        if cfg.is_encdec:
            spec = {
                "self": spec,
                "xk": jax.ShapeDtypeStruct((B, enc_len, cfg.n_kv_heads, cfg.hd), jnp.bfloat16),
                "xv": jax.ShapeDtypeStruct((B, enc_len, cfg.n_kv_heads, cfg.hd), jnp.bfloat16),
            }
        cache[f"tail{i}"] = spec
    return cache


def init_cache(cfg: ModelConfig, B: int, cache_len: int, enc_len: int = 0):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        decode_cache_specs(cfg, B, cache_len, enc_len),
    )
