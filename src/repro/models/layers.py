"""Elementary layers: norms, RoPE, activations, dense/MoE FFN.

Pure functions over (params-dict, activations); bf16-friendly (reductions in
fp32). The heavy attention paths live in attention.py / the Pallas kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(
        x.dtype
    )


def layernorm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(cfg, x, scale, bias=None):
    if cfg.norm == "layernorm":
        return layernorm(x, scale, bias if bias is not None else jnp.zeros_like(scale))
    return rmsnorm(x, scale)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def dense_ffn(cfg, p, prefix: str, x: jax.Array) -> jax.Array:
    """Gated FFN (SwiGLU/GeGLU): out = W2( act(W_g x) * (W_u x) )."""
    g = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}.wg"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}.wu"].astype(x.dtype))
    h = act_fn(cfg.act)(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p[f"{prefix}.wd"].astype(x.dtype))


def moe_ffn(cfg, p, prefix: str, x: jax.Array) -> jax.Array:
    """Top-k routed MoE with GShard-style capacity dispatch.

    Dense dispatch/combine einsums so GSPMD can shard the expert dim over the
    "model" mesh axis (expert parallelism); the dispatch one-hots lower to
    all-to-alls when tokens and experts live on different axes.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n_tok = B * S
    cap = max(int(cfg.capacity_factor * K * n_tok / (E * max(B, 1))), 1)  # per batch row
    xt = x.reshape(B, S, D)

    logits = jnp.einsum("bsd,de->bse", xt, p[f"{prefix}.router"].astype(x.dtype))
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # [B,S,E]
    topv, topi = jax.lax.top_k(gates, K)  # [B,S,K]
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # position of each (token, k) in its expert's buffer
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)  # [B,S,K,E]
    pos = (
        jnp.cumsum(onehot.reshape(B, S * K, E), axis=1).reshape(B, S, K, E) - onehot
    )
    in_cap = pos < cap
    disp = onehot * in_cap  # [B,S,K,E]
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)  # [B,S,K,E,C]
    dispatch = jnp.einsum("bske,bskec->bsec", disp, pos_oh)  # [B,S,E,C]
    combine = jnp.einsum("bske,bskec,bsk->bsec", disp, pos_oh, topv.astype(jnp.float32))

    xe = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), xt)  # [B,E,C,D]
    g = jnp.einsum("becd,edf->becf", xe, p[f"{prefix}.we_g"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", xe, p[f"{prefix}.we_u"].astype(x.dtype))
    h = act_fn(cfg.act)(g) * u
    ye = jnp.einsum("becf,efd->becd", h, p[f"{prefix}.we_d"].astype(x.dtype))
    return jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), ye)


def ffn(cfg, p, prefix: str, kind: str, x: jax.Array) -> jax.Array:
    if kind == "moe":
        return moe_ffn(cfg, p, prefix, x)
    if kind == "none":
        return jnp.zeros_like(x)
    return dense_ffn(cfg, p, prefix, x)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


def embed_lookup(table: jax.Array, ids: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """One-hot-matmul embedding lookup. A plain gather from a (vocab->model,
    embed->data)-sharded table forces SPMD to fully rematerialize the table;
    the iota-one-hot dot partitions cleanly (MaxText's iota-embed)."""
    oh = jax.nn.one_hot(ids, table.shape[0], dtype=dtype)
    return jnp.einsum("...v,vd->...d", oh, table.astype(dtype))


def gather_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """sum(one_hot(labels) * logits) — collective-friendly take_along_axis."""
    oh = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    return jnp.sum(oh * logits, axis=-1)
