"""RG-LRU recurrent block (Griffin, arXiv:2402.19427 / RecurrentGemma).

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_i x_t + b_i)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses jax.lax.associative_scan over the diagonal linear recurrence
(parallel depth O(log S) — this is what makes the 500k-token cells feasible);
decode is the O(1) elementwise update. The block is the Griffin recurrent
block: y = W_out( GeLU(W_gate xn) * RGLRU(conv4(W_x xn)) ).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm
from repro.models.xlstm import causal_conv

_C = 8.0


def _gates(p, prefix, xr):
    r = jax.nn.sigmoid(
        (
            jnp.einsum("bsd,de->bse", xr, p[f"{prefix}.wa"]).astype(jnp.float32)
            + p[f"{prefix}.ba"].astype(jnp.float32)
        )
    )
    i = jax.nn.sigmoid(
        (
            jnp.einsum("bsd,de->bse", xr, p[f"{prefix}.wi"]).astype(jnp.float32)
            + p[f"{prefix}.bi"].astype(jnp.float32)
        )
    )
    lam = jax.nn.softplus(p[f"{prefix}.lam"].astype(jnp.float32))  # [d_rnn]
    log_a = -_C * lam * r  # [B,S,d_rnn]
    return log_a, i


def rglru_scan(log_a, gx):
    """h_t = a_t h_{t-1} + b_t via associative scan. log_a/gx: [B,S,E]."""

    def combine(l, r):
        (la1, b1), (la2, b2) = l, r
        return la1 + la2, jnp.exp(la2) * b1 + b2

    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0)) * gx
    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rglru_block(cfg, p, prefix, x, *, cache=None, return_state: bool = False):
    """Griffin recurrent residual block. Returns (out, new_cache)."""
    B, S, D = x.shape
    xn = rmsnorm(x, p[f"{prefix}.ln"])
    gate = jax.nn.gelu(
        jnp.einsum("bsd,de->bse", xn, p[f"{prefix}.wgate"].astype(x.dtype))
    )
    xr = jnp.einsum("bsd,de->bse", xn, p[f"{prefix}.wx"].astype(x.dtype))
    if cache is None:
        xc = causal_conv(xr, p[f"{prefix}.conv"].astype(x.dtype))
        log_a, i = _gates(p, prefix, xc)
        h = rglru_scan(log_a, i * xc.astype(jnp.float32))
        new_cache = None
        if return_state:
            W = p[f"{prefix}.conv"].shape[0]
            new_cache = {"h": h[:, -1], "conv": xr[:, -(W - 1) :, :]}
    else:
        buf = jnp.concatenate([cache["conv"], xr], axis=1)
        xc = jnp.einsum("bwd,wd->bd", buf, p[f"{prefix}.conv"].astype(x.dtype))[:, None]
        conv_cache = buf[:, 1:]
        log_a, i = _gates(p, prefix, xc)
        a = jnp.exp(log_a[:, 0])
        b = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0)) * (
            i[:, 0] * xc[:, 0].astype(jnp.float32)
        )
        h_new = a * cache["h"] + b
        h = h_new[:, None]
        new_cache = {"h": h_new, "conv": conv_cache}
    y = h.astype(x.dtype) * gate
    return jnp.einsum("bse,ed->bsd", y, p[f"{prefix}.wout"].astype(x.dtype)), new_cache