"""Analytic FLOPs / HBM-traffic model per (architecture x shape cell).

XLA's cost_analysis() counts while-loop bodies ONCE (scan over layer groups,
gradient-accumulation scan, attention q-chunk maps), so its raw FLOPs
undercount by the trip counts. This module computes the exact dense-algebra
FLOPs of our implementation (every einsum is known), which is what the
roofline compute term uses; the dry-run numbers are kept as diagnostics.

Conventions: 1 MAC = 2 FLOPs. Backward = 2x forward; per-layer-group remat
adds ~1x forward for the scanned stack. MODEL_FLOPS = 6*N*D_tokens (dense) or
6*N_active*D_tokens (MoE), reported separately to expose remat/dispatch waste.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, ShapeCell


def _attn_layer_flops(cfg: ModelConfig, S: int, mixer: str, kv_len: int | None = None):
    """Forward FLOPs for one attention layer over S query tokens."""
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    proj = 2 * S * D * (H * hd) + 2 * 2 * S * D * (KV * hd) + 2 * S * (H * hd) * D
    if cfg.qkv_bias:
        proj += S * (H + 2 * KV) * hd
    if kv_len is None:  # self attention over S
        if mixer == "swa" and cfg.window < S:
            eff = cfg.window  # banded
        elif mixer == "cla" and cfg.window < S:
            eff = cfg.window // 2 + 1  # same-chunk average
        else:
            eff = (S + 1) / 2  # causal average
        sc = 2 * 2 * S * eff * H * hd  # QK^T + PV
    else:
        eff = min(kv_len, cfg.window) if mixer in ("swa", "cla") and cfg.window < kv_len else kv_len
        sc = 2 * 2 * S * eff * H * hd
    return proj + sc


def _mla_layer_flops(cfg: ModelConfig, S: int, kv_len: int | None = None):
    D, H = cfg.d_model, cfg.n_heads
    qk = cfg.nope_head_dim + cfg.rope_head_dim
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    proj = (
        2 * S * D * r_q
        + 2 * S * r_q * H * qk
        + 2 * S * D * (r_kv + cfg.rope_head_dim)
        + 2 * S * r_kv * H * (cfg.nope_head_dim + cfg.v_hd)
        + 2 * S * H * cfg.v_hd * D
    )
    L = (S + 1) / 2 if kv_len is None else kv_len
    if kv_len is not None:
        # absorbed decode: scores against the latent cache
        sc = 2 * S * H * cfg.nope_head_dim * r_kv + 2 * S * H * L * (
            r_kv + cfg.rope_head_dim
        ) + 2 * S * H * L * r_kv + 2 * S * H * r_kv * cfg.v_hd
    else:
        sc = 2 * S * L * H * qk + 2 * S * L * H * cfg.v_hd  # QK^T + PV
    return proj + sc


def _mlstm_layer_flops(cfg: ModelConfig, S: int, decode: bool = False):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    proj = 2 * S * D * 2 * D + 3 * 2 * S * D * D + 2 * S * D * D + 2 * S * D * 2 * H
    if decode:
        cell = S * H * (3 * dh * dh + 4 * dh)  # C update + read per token
    else:
        cell = 2 * 2 * S * ((S + 1) / 2) * H * dh  # parallel form ~ attention
    return proj + cell + 4 * 4 * S * D  # conv4


def _slstm_layer_flops(cfg: ModelConfig, S: int):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    return 2 * S * D * 4 * D + S * 4 * H * 2 * dh * dh + 2 * S * D * D


def _rglru_layer_flops(cfg: ModelConfig, S: int):
    D = cfg.d_model
    E = int(cfg.rnn_scale * D)
    proj = 2 * S * D * E * 2 + 2 * S * E * D  # wgate, wx, wout
    gates = 2 * 2 * S * E * E  # wa, wi
    scan = 8 * S * E  # elementwise recurrence
    conv = 2 * cfg.rglru_conv_width * S * E
    return proj + gates + scan + conv


def _ffn_flops(cfg: ModelConfig, S: int, kind: str):
    D, F = cfg.d_model, cfg.d_ff
    if kind == "none":
        return 0
    if kind == "moe":
        E, K = cfg.n_experts, cfg.top_k
        cap_tokens = cfg.capacity_factor * K * S  # tokens processed by experts
        expert = 3 * 2 * cap_tokens * D * F
        router = 2 * S * D * E
        # dispatch/combine one-hot einsums: [S,E,C]x[S,D] twice
        cap = cfg.capacity_factor * K * S / E
        dispatch = 2 * 2 * S * E * cap * D
        return expert + router + dispatch
    return 3 * 2 * S * D * F


def _layer_flops(cfg: ModelConfig, mixer: str, fk: str, S: int, kv_len=None, decode=False):
    if mixer in ("gqa", "swa", "cla"):
        f = _attn_layer_flops(cfg, S, mixer, kv_len)
    elif mixer == "mla":
        f = _mla_layer_flops(cfg, S, kv_len)
    elif mixer == "mlstm":
        f = _mlstm_layer_flops(cfg, S, decode)
    elif mixer == "slstm":
        f = _slstm_layer_flops(cfg, S)
    elif mixer == "rglru":
        f = _rglru_layer_flops(cfg, S)
    else:
        raise ValueError(mixer)
    return f + _ffn_flops(cfg, S, fk)


def _all_layers(cfg: ModelConfig):
    from repro.models.stack import n_groups, tail_layers

    layers = list(cfg.pattern) * n_groups(cfg) + list(tail_layers(cfg))
    return layers


def forward_flops(cfg: ModelConfig, batch: int, S: int, kv_len=None, decode=False) -> float:
    """Forward FLOPs for `batch` sequences of S tokens (per-token decode when
    decode=True, attending to kv_len cache)."""
    total = 0.0
    for mixer, fk in _all_layers(cfg):
        total += _layer_flops(cfg, mixer, fk, S, kv_len=kv_len, decode=decode)
    if cfg.is_encdec:
        # encoder layers + cross attention in each decoder layer
        enc_S = S  # frames
        for _ in range(cfg.n_enc_layers):
            total += _layer_flops(cfg, "gqa", "dense", enc_S)
        D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
        dec_S = max(S // 4, 128) if not decode else S
        xa = 2 * dec_S * D * H * hd * 2 + 2 * 2 * dec_S * enc_S * H * hd
        total += cfg.n_layers * xa
    # embedding one-hot dot + logits + CE
    V, D = cfg.vocab, cfg.d_model
    total += 2 * S * V * D  # one-hot lookup
    total += 2 * S * D * V  # logits
    return total * batch


_REMAT_FACTOR = {"full": 4.0, "dots": 3.1, "none": 3.0}


def cell_flops(cfg: ModelConfig, cell: ShapeCell, remat: str = "full") -> dict:
    """Returns dict(total=HLO-equivalent flops, model=6*N*D).

    remat: "full"  — checkpoint per layer group: +1x forward recompute.
           "dots"  — save matmul outputs; recompute only elementwise (~+0.1x).
           "none"  — no recompute (fwd + 2x bwd).
    """
    B, S = cell.global_batch, cell.seq_len
    act = cfg.params_active()
    if cell.kind == "train":
        dec_S = max(S // 4, 128) if cfg.is_encdec else S
        fwd = forward_flops(cfg, B, S)
        total = _REMAT_FACTOR[remat] * fwd
        model = 6.0 * act * B * (dec_S if cfg.is_encdec else S)
        return {"total": total, "model": model}
    if cell.kind == "prefill":
        fwd = forward_flops(cfg, B, S)
        return {"total": fwd, "model": 2.0 * act * B * S}
    # decode: one token, cache of S
    fwd = forward_flops(cfg, B, 1, kv_len=S, decode=True)
    return {"total": fwd, "model": 2.0 * act * B}


# ---------------------------------------------------------------------------
# HBM traffic model
# ---------------------------------------------------------------------------


def cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """Total decode-cache bytes for batch B, context S."""
    from repro.models.stack import _cache_capacity

    total = 0.0
    for mixer, _ in _all_layers(cfg):
        if mixer in ("gqa", "swa", "cla"):
            cap = _cache_capacity(cfg, mixer, S)
            if cfg.kv_cache_dtype == "int8":
                total += 2 * B * cap * cfg.n_kv_heads * (cfg.hd * 1 + 4)  # int8+scale
            else:
                total += 2 * B * cap * cfg.n_kv_heads * cfg.hd * 2  # k+v bf16
        elif mixer == "mla":
            total += B * S * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2
        elif mixer == "mlstm":
            dh = cfg.d_model // cfg.n_heads
            total += B * cfg.n_heads * (dh * dh + dh + 1) * 4 + B * 3 * cfg.d_model * 2
        elif mixer == "slstm":
            total += 4 * B * cfg.d_model * 4
        elif mixer == "rglru":
            E = int(cfg.rnn_scale * cfg.d_model)
            total += B * E * 4 + B * (cfg.rglru_conv_width - 1) * E * 2
    if cfg.is_encdec:
        total += cfg.n_layers * 2 * B * S * cfg.n_kv_heads * cfg.hd * 2
    return total


def cell_hbm_bytes(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Whole-step HBM traffic (all chips combined).

    train : params bf16 read 3x (fwd/bwd/remat) * accum-independent +
            grads f32 rw + optimizer m/v read+write + params f32 rw +
            checkpointed activations write+read.
    decode: params read once + cache read + cache write (delta) + activations.
    prefill: params read + activations + cache write.
    """
    P = cfg.params_dense()
    B, S = cell.global_batch, cell.seq_len
    D = cfg.d_model
    L = cfg.n_layers
    if cell.kind == "train":
        wb = 3 * P * 2  # bf16 weight reads (fwd, bwd, remat recompute)
        opt = P * 4 * 6  # m,v read+write + params f32 read+write
        grads = P * 4 * 2
        acts = 2 * B * S * D * 2 * L  # checkpoint saves + reads (bf16)
        return wb + opt + grads + acts
    if cell.kind == "prefill":
        return P * 2 + 2 * B * S * D * 2 * L + cache_bytes(cfg, B, S)
    # decode
    return P * 2 + cache_bytes(cfg, B, S) + 2 * B * D * 2 * L
