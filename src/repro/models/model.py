"""Step functions (train / prefill / decode) + abstract input specs per
(architecture x shape) cell. These are the functions the launcher jits, the
dry-run lowers, and the smoke tests execute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import stack
from repro.models.config import ModelConfig, ShapeCell
from repro.optim import adamw


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy (fp32 reduction) + small z-loss."""
    from repro.models.layers import gather_logits

    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = gather_logits(lf, labels)
    ce = jnp.mean(lse - gold)
    zloss = 1e-4 * jnp.mean(lse**2)
    return ce + zloss


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, remat=False) -> jax.Array:
    logits = stack.forward_train(cfg, params, batch, remat=remat)
    labels = batch["dec_labels"] if cfg.is_encdec else batch["labels"]
    if cfg.frontend == "vision":
        # loss only on the text tokens that follow the patch prefix
        logits = logits[:, -labels.shape[1] :]
    return cross_entropy(logits, labels)


def make_train_step(cfg: ModelConfig, opt: adamw.AdamWConfig, accum: int = 1, remat=False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    accum > 1 splits the global batch into microbatches (gradient
    accumulation) — bounds live activation memory on the large cells.
    remat=True applies per-layer-group activation checkpointing.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(cfg, p, batch, remat=remat))(params)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch
            )

            def body(carry, mb):
                acc, lsum = carry
                l, g = grads_of(params, mb)
                return (jax.tree.map(jnp.add, acc, g), lsum + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(body, (zero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        params, opt_state, stats = adamw.apply_updates(opt, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **stats}

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return stack.forward_prefill(cfg, params, batch, cache_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, token, pos, cache):
        return stack.forward_decode(cfg, params, token, pos, cache)

    return serve_step


# ---------------------------------------------------------------------------
# abstract input specs per shape cell (ShapeDtypeStruct — no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Abstract inputs for jit(...).lower(**specs). Keys match step args."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), i32)

    if cell.kind == "train":
        if cfg.is_encdec:
            s_dec = max(S // 4, 128)
            batch = {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), jnp.bfloat16),
                "dec_tokens": tok(B, s_dec),
                "dec_labels": tok(B, s_dec),
            }
        elif cfg.frontend == "vision":
            P = min(1024, S // 4)
            batch = {
                "patches": jax.ShapeDtypeStruct((B, P, cfg.frontend_dim), jnp.bfloat16),
                "tokens": tok(B, S - P),
                "labels": tok(B, S),  # loss over full (patch+text) positions - P
            }
            batch["labels"] = tok(B, S - P)
        else:
            batch = {"tokens": tok(B, S), "labels": tok(B, S)}
        return {"batch": batch}

    if cell.kind == "prefill":
        if cfg.is_encdec:
            s_dec = max(S // 4, 128)
            batch = {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), jnp.bfloat16),
                "dec_tokens": tok(B, s_dec),
            }
        elif cfg.frontend == "vision":
            P = min(1024, S // 4)
            batch = {
                "patches": jax.ShapeDtypeStruct((B, P, cfg.frontend_dim), jnp.bfloat16),
                "tokens": tok(B, S - P),
            }
        else:
            batch = {"tokens": tok(B, S)}
        return {"batch": batch}

    # decode: one new token against a cache of size seq_len
    enc_len = max(S // 4, 128) if cfg.is_encdec else 0
    cache_len = S if not cfg.is_encdec else S  # self-attn cache length
    cache = stack.decode_cache_specs(cfg, B, cache_len, enc_len=S if cfg.is_encdec else 0)
    return {
        "token": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((B,), i32),
        "cache": cache,
    }


def abstract_train_state(cfg: ModelConfig):
    """(params, opt_state) ShapeDtypeStructs for the train dry-run."""
    from repro.models.schema import abstract_params

    ap = abstract_params(stack.build_schema(cfg))
    return ap, adamw.abstract_state(ap)
